"""Render reports/dryrun/*.json into the EXPERIMENTS.md §Dry-run and
§Roofline markdown tables, reports/serving/*.json (written by
benchmarks/serving_throughput.py) into the §Serving table, and
reports/bench/BENCH_moe_dispatch.json (benchmarks/moe_dispatch.py) into
the §MoE dispatch table.

  PYTHONPATH=src python -m benchmarks.report_md > reports/roofline_tables.md
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

DRYRUN_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "reports", "dryrun"))
SERVING_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "reports", "serving"))
BENCH_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "reports", "bench"))

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    recs = {}
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def main():
    recs = load()
    archs = sorted({k[0] for k in recs})

    print("### Dry-run status (arch x shape x mesh)\n")
    print("| arch | shape | single-pod (16x16) | multi-pod (2x16x16) | "
          "wmode | HBM/chip (GB) |")
    print("|---|---|---|---|---|---|")
    for a in archs:
        for s in SHAPE_ORDER:
            r1 = recs.get((a, s, "pod16x16"))
            r2 = recs.get((a, s, "pod2x16x16"))
            if r1 is None and r2 is None:
                continue
            st1 = r1["status"] if r1 else "—"
            st2 = r2["status"] if r2 else "—"
            wm = (r1 or r2).get("weight_mode", "—")
            hbm = (f"{r1['memory']['peak_per_device_gb']:.2f}"
                   if r1 and r1["status"] == "ok" else "—")
            print(f"| {a} | {s} | {st1} | {st2} | {wm} | {hbm} |")

    print("\n### Roofline terms (single-pod, 256 chips; seconds per step)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL/HLO flops | notes |")
    print("|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "pod16x16"))
            if not r:
                continue
            if r["status"] == "skipped":
                print(f"| {a} | {s} | — | — | — | skipped | — | "
                      f"sub-quadratic rule |")
                continue
            if r["status"] != "ok":
                print(f"| {a} | {s} | — | — | — | ERROR | — | |")
                continue
            rf = r["roofline"]
            dom = rf["dominant"].replace("_s", "")
            note = ""
            if r["memory"]["peak_per_device_gb"] > 16:
                note = f"over 16GB HBM ({r['memory']['peak_per_device_gb']:.0f}GB)"
            print(f"| {a} | {s} | {fmt_ms(rf['compute_s'])}ms "
                  f"| {fmt_ms(rf['memory_s'])}ms "
                  f"| {fmt_ms(rf['collective_s'])}ms | **{dom}** "
                  f"| {rf['useful_flops_ratio']:.2f} | {note} |")

    # dominant-term stats
    doms = defaultdict(int)
    for (a, s, m), r in recs.items():
        if m == "pod16x16" and r["status"] == "ok":
            doms[r["roofline"]["dominant"]] += 1
    print("\nDominant-term distribution (single-pod):",
          dict(doms))

    serving_section()
    moe_dispatch_section()
    ep_exchange_section()
    policy_ablation_section()
    offload_stream_section()


def moe_dispatch_section():
    """§MoE dispatch: dense capacity-bucket sweep vs the sparse decode
    fast path (benchmarks/moe_dispatch.py, DESIGN.md §4).

    Reading the columns: the dense path computes E x C bucket rows every
    step regardless of workload; the sparse path gathers the activated
    experts' weights and computes B x K rows.  The speedup column is the
    dispatch overcompute the workload-aware path removes at decode; rows
    where it dips below 1x are the regime the static selection rule
    assigns to the dense path (small E, larger batch)."""
    f = os.path.join(BENCH_DIR, "BENCH_moe_dispatch.json")
    if not os.path.exists(f):
        return
    rec = json.load(open(f))
    print("\n### MoE dispatch: dense sweep vs sparse decode fast path\n")
    print(f"(backend={rec['backend']}, d_model={rec['d_model']}, "
          f"d_expert={rec['d_expert']})\n")
    for line in moe_dispatch_table(rec["rows"]):
        print(line)
    print("\n(µs/step on one MoE layer; production decode picks the "
          "faster path statically from shapes — see "
          "repro/models/moe.py::use_sparse_path.)")


def moe_dispatch_table(rows):
    """Markdown table lines for moe_dispatch records (single source of
    the column layout — the benchmark's stdout uses it too)."""
    out = ["| E | batch | dense µs | sparse µs | speedup | dense rows | "
           "sparse rows |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['E']} | {r['batch']} | {r['dense_us']:.1f} "
                   f"| {r['sparse_us']:.1f} | {r['speedup']:.2f}x "
                   f"| {r['dense_rows']} | {r['sparse_rows']} |")
    return out


def ep_exchange_section():
    """§EP exchange: workload-sized ragged all_to_all vs the dense
    full-capacity exchange (benchmarks/ep_exchange.py, DESIGN.md §6).

    Reading the columns: the dense path ships E x C bucket rows through
    both all_to_alls every step; the ragged path exchanges counts first
    and ships E x C_x, the smallest static ladder rung covering the
    step's global max per-(device, expert) demand.  bytes% is the
    analytic on-link traffic ratio (incl. the count exchange); host-CPU
    µs tracks dispatch/compute savings, not a real interconnect."""
    f = os.path.join(BENCH_DIR, "BENCH_ep_exchange.json")
    if not os.path.exists(f):
        return
    rec = json.load(open(f))
    print("\n### EP exchange: ragged (workload-sized) vs dense all_to_all\n")
    print(f"(backend={rec['backend']}, tp={rec['tp']}, E={rec['E']}, "
          f"d_model={rec['d_model']}, smoke={rec['smoke']})\n")
    for line in ep_exchange_table(rec["rows"]):
        print(line)
    print("\n(C_x: exchanged bucket capacity, picked per step from the "
          "static ladder by the count exchange — see "
          "repro/models/moe_ep.py.)")
    res = rec.get("resilience")
    if res:
        print("\n#### EP resilience: degraded-link expert re-route\n")
        print(f"(fault={res['faults']} on the {res['topology']} fabric, "
              f"tp={res['tp']}; outputs bit-identical across all trials: "
              f"{'yes' if res['verdicts']['static_bit_exact'] and res['verdicts']['reroute_bit_exact'] else 'NO'})\n")
        for line in ep_resilience_table(res):
            print(line)
        print("\n(fault-window ms/step charges the injected per-link "
              "slowdown as wall time; degraded-pair KB is the analytic "
              "demand crossing the slow link — the re-route moves the "
              "victim's hot experts off it. See repro/launch/ep_serve.py, "
              "DESIGN.md §13.)")


def ep_exchange_table(rows):
    """Markdown table lines for ep_exchange records (single source of the
    column layout — the benchmark's stdout uses it too)."""
    out = ["| routing | dtype | C | C_x | link bytes | dense µs | "
           "ragged µs | parity |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['routing']} | {r['dtype']} | {r['C']} "
                   f"| {r['cx']} | {100 * r['byte_ratio']:.0f}% "
                   f"| {r['dense_us']:.0f} | {r['ragged_us']:.0f} "
                   f"| {r['parity_max_err']:.1e} |")
    return out


def ep_resilience_table(res):
    """Markdown table lines for the EP resilience record (single source
    of the column layout — benchmarks/ep_exchange.py stdout uses it
    too)."""
    out = ["| trial | ms/step | fault-window ms/step | degraded-pair "
           "KB/step | reroutes |",
           "|---|---|---|---|---|"]
    for tr in res["trials"]:
        fm = tr["fault_ms_per_step"]
        fb = tr["fault_pair_bytes_per_step"]
        out.append(f"| {tr['name']} | {tr['ms_per_step']:.1f} "
                   f"| {'—' if fm is None else f'{fm:.1f}'} "
                   f"| {'—' if fb is None else f'{fb / 1e3:.1f}'} "
                   f"| {tr['reroutes']} |")
    return out


def policy_ablation_section():
    """§Policy ablation: every registered OffloadPolicy on one shared
    routing trace (benchmarks/policy_ablation.py, DESIGN.md §7).

    Reading the columns: decode tok/s and makespan are *modeled* under
    the paper's local-PC timing model (DESIGN.md §2 — expert compute
    never leaves the accelerator in this container); hit% and prefetch
    accuracy are measured on the real routing; wall µs/step is the
    policy's actual in-graph overhead in the jitted decode step; exec
    hit% is drained from the device-side accumulator of the executed
    decode run (it differs from the modeled column because the executed
    run decodes its own tokens rather than replaying the shared trace)."""
    f = os.path.join(BENCH_DIR, "BENCH_policy_ablation.json")
    if not os.path.exists(f):
        return
    rec = json.load(open(f))
    print("\n### Policy ablation (one OffloadPolicy API, "
          "simulator + jitted decode)\n")
    print(f"(arch={rec['arch']}, backend={rec['backend']}, "
          f"smoke={rec['smoke']}, "
          f"cache_ratio={rec['workload']['cache_ratio']})\n")
    for line in policy_ablation_table(rec["rows"]):
        print(line)
    print("\n(decode tok/s + makespan: paper timing model; hit%/prefetch "
          "acc: measured routing; wall µs: jitted decode step on this "
          "host — see repro/core/policy.py.)")


def policy_ablation_table(rows):
    """Markdown table lines for policy_ablation records (single source of
    the column layout — the benchmark's stdout uses it too)."""
    out = ["| policy | decode tok/s (model) | makespan est (s) | hit% | "
           "prefetch acc% | wall µs/step | exec hit% |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        eh = (f"{100 * r['exec_hit_rate']:.1f}"
              if r.get("exec_hit_rate") is not None else "—")
        out.append(f"| {r['policy']} | {r['decode_tok_s']:.2f} "
                   f"| {r['makespan_est_s']:.4f} "
                   f"| {100 * r['hit_rate']:.1f} "
                   f"| {100 * r['prefetch_acc']:.1f} "
                   f"| {r['step_wall_us']:.0f} | {eh} |")
    return out


def offload_stream_section():
    """§Offload streaming: modeled vs blocking vs overlapped physical
    expert residency (benchmarks/offload_stream.py, DESIGN.md §8).

    Reading the columns: wall µs/step is measured end-to-end (decode +
    pool streaming + the serving loop's per-step sync).  "modeled" keeps
    every expert on device (no copies — the floor); "blocking" streams
    each step's slot plan on the critical path; "overlap" issues the
    same copies right after the decode dispatch so they hide behind the
    step's compute.  H2D experts/step counts newly streamed experts;
    H2D MB/step is the actual staged traffic into the device slot pool
    (including double-buffer re-applies and pow2 staging padding);
    fallback rows/step are (token, k) slots a step served from the host
    tier because the pool missed."""
    f = os.path.join(BENCH_DIR, "BENCH_offload_stream.json")
    if not os.path.exists(f):
        return
    rec = json.load(open(f))
    print("\n### Offload streaming (physical expert residency)\n")
    lf = rec["link_fit"]
    print(f"(arch={rec['arch']}, backend={rec['backend']}, "
          f"smoke={rec['smoke']}, E={rec['workload']['experts']}, "
          f"B={rec['workload']['batch']}, "
          f"fallback={rec['workload']['fallback']}; measured link "
          f"{lf['gbps']:.1f} GB/s / {lf['latency_us']:.0f} µs)\n")
    for line in offload_stream_table(rec["rows"]):
        print(line)
    if "overlap_speedup" in rec:
        print(f"\n(overlap vs blocking: {rec['overlap_speedup']:.2f}x — "
              "the wall-clock value of hiding H2D expert streaming behind "
              "decode compute; see repro/serving/expert_store.py.)")
    if any("breakdown" in r for r in rec["rows"]):
        print("\n#### Pipeline breakdown (per-step, timed window)\n")
        for line in offload_breakdown_table(rec["rows"]):
            print(line)
        host = rec.get("host", {})
        if host:
            print(f"\n(host: {host.get('affinity_cores')} usable cores of "
                  f"{host.get('cpu_count')}, {host.get('active_threads')} "
                  f"live threads — oversubscribed="
                  f"{host.get('oversubscribed')}; copy/compute overlap "
                  "needs idle host cores to drive the transfer.)")
        if "pipelined_speedup_vs_overlap" in rec:
            print(f"(pipelined vs overlap: "
                  f"{rec['pipelined_speedup_vs_overlap']:.2f}x, fewer "
                  f"misses={rec.get('pipelined_fewer_misses')} — per-layer "
                  "inject streaming keeps decisions t+1-fresh with the "
                  "commit amortized across layers; DESIGN.md §9.)")
    pf = rec.get("prefill")
    if pf:
        print("\n#### Offload streaming prefill (slot-pool sweeps, "
              "DESIGN.md §11)\n")
        for line in offload_prefill_table(pf):
            print(line)
        print(f"\n(prompt_len={rec['workload'].get('prompt_len')}; "
              "physical modes prefill with STRIPPED expert params — each "
              "MoE layer assembles its dense sweep from resident pool "
              "rows plus streamed waves of misses; exact = tokens AND "
              "caches bit-identical to the full-resident reference.)")
    ft = rec.get("fault_tolerance")
    if ft:
        print("\n#### Fault tolerance (watchdog + degradation ladder)\n")
        for line in offload_fault_table(ft):
            print(line)
        trans = ", ".join(f"step {s}: {a}→{b}"
                          for s, a, b in ft.get("transitions", []))
        print(f"\n(faults={ft['faults']} on mode={ft['mode']}; "
              + (f"ladder: {trans}; " if trans else "")
              + "exactness is vs a full-resident reference with fixed "
              "token injection — streaming faults recover bit-exact, the "
              "int8 little tier is allclose by design; DESIGN.md §10.)")


def offload_stream_table(rows):
    """Markdown table lines for offload_stream records (single source of
    the column layout — the benchmark's stdout uses it too)."""
    out = ["| mode | wall µs/step | decode tok/s | H2D experts/step | "
           "H2D MB/step | fallback rows/step |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['mode']} | {r['wall_us_per_step']:.0f} "
                   f"| {r['decode_tok_s']:.1f} "
                   f"| {r['h2d_rows_per_step']:.2f} "
                   f"| {r['h2d_mb_per_step']:.3f} "
                   f"| {r['fallback_rows_per_step']:.2f} |")
    return out


def offload_prefill_table(rows):
    """Markdown table lines for the prefill-phase records written by
    offload_stream (single source of the column layout — the benchmark's
    stdout uses it too).  "peak device MB" is the analytic expert-weight
    footprint during one sweep (resident pool + one transient (E, ...)
    layer stack + the wave staging buffer — ``memory_layout``); for
    "modeled" it is the full-resident stack the offload replaces."""
    out = ["| mode | wall ms | prefill tok/s | streamed experts | waves | "
           "H2D MB | host rows | peak device MB | exact |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        peak = r.get("peak_pool_bytes")
        peak_mb = f"{peak / 1e6:.1f}" if peak is not None else "—"
        out.append(f"| {r['mode']} | {r['wall_ms']:.1f} "
                   f"| {r['prefill_tok_s']:.0f} "
                   f"| {r['fetch_rows_per_prefill']:.1f} "
                   f"| {r['waves_per_prefill']:.1f} "
                   f"| {r['h2d_mb_per_prefill']:.3f} "
                   f"| {r['host_rows_per_prefill']:.1f} "
                   f"| {peak_mb} "
                   f"| {'yes' if r['exact_vs_modeled'] else 'NO'} |")
    return out


def offload_fault_table(ft):
    """Markdown table lines for the fault_tolerance record written by
    ``offload_stream --faults`` (single source of the column layout — the
    benchmark's stdout uses it too).  One row per trial phase: median
    ms/step while healthy, under the injected fault (watchdog + ladder
    reacting), and after the link heals, plus the recovery counters."""
    pm = ft.get("phase_ms", {})
    ps = ft.get("phase_steps", {})
    c = ft.get("counters", {})
    v = ft.get("verdicts", {})
    fmt = lambda x: f"{x:.2f}" if x is not None else "—"
    out = ["| phase | steps | ms/step | exactness |",
           "|---|---|---|---|"]
    out.append(f"| healthy | {ps.get('healthy', 0)} "
               f"| {fmt(pm.get('healthy'))} | bit-exact |")
    little = ft.get("little_engaged")
    out.append(f"| fault | {ps.get('fault', 0)} | {fmt(pm.get('fault'))} "
               f"| {'allclose (little tier)' if little else 'bit-exact'} |")
    out.append(f"| recovered | {ps.get('recovered', 0)} "
               f"| {fmt(pm.get('recovered'))} | bit-exact |")
    ttr = ft.get("time_to_recover_steps")
    out.append("")
    out.append(f"recovery: retries={c.get('retries', 0)} "
               f"deadline_misses={c.get('deadline_misses', 0)} "
               f"corrupt_caught={c.get('corrupt_caught', 0)} "
               f"restaged={c.get('restaged_rows', 0)} "
               f"little_steps={c.get('little_steps', 0)} "
               f"probes={c.get('probes', 0)} "
               f"time_to_recover={ttr if ttr is not None else '—'} steps "
               f"| ok={all(v.values()) if v else '—'}")
    return out


def offload_breakdown_table(rows):
    """Markdown table lines for the per-step timing breakdown recorded by
    offload_stream (DESIGN.md §9): host stage / commit time, the full
    pre-dispatch span (what the decode waits on before it can launch)
    and the dispatch-to-sync span.  Rows without a breakdown ("modeled")
    print dashes."""
    out = ["| mode | stage ms | commit ms | pre-dispatch ms | "
           "compute+sync ms | miss rows | H2D MB |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        b = r.get("breakdown")
        if not b:
            out.append(f"| {r['mode']} | — | — | — | — | "
                       f"{r['fallback_rows_per_step']:.2f} | "
                       f"{r['h2d_mb_per_step']:.3f} |")
            continue
        out.append(f"| {r['mode']} | {b['stage_ms']:.3f} "
                   f"| {b['commit_ms']:.3f} "
                   f"| {b['pre_dispatch_ms']:.3f} "
                   f"| {b['compute_sync_ms']:.3f} "
                   f"| {r['fallback_rows_per_step']:.2f} "
                   f"| {r['h2d_mb_per_step']:.3f} |")
    return out


def serving_section():
    """§Serving: continuous batching vs wave under Poisson arrivals.

    How to (re)generate a row:
      PYTHONPATH=src python -m benchmarks.serving_throughput \
          --arch mixtral-8x7b --requests 24 --batch 4 --rate 8

    Reading the columns:
      decode tok/s — emitted decode tokens / decode wall time.  Wave mode
        loses it to pad-and-lockstep dead slots; continuous batching
        refills freed slots every step, so occupancy (occ, mean live slots
        per step) stays near the batch size.
      TTFT p50/p99 — arrival to FIRST token.  Bounded by admission delay:
        a wave admits only when the previous wave drains; continuous
        batching admits as soon as any slot frees.
      lat p50/p99 — arrival to LAST token; p99 is the tail a serving SLA
        cares about and is dominated by queueing under bursty arrivals.
    """
    files = sorted(glob.glob(os.path.join(SERVING_DIR, "*.json")))
    if not files:
        return
    print("\n### Serving throughput (Poisson arrivals, mixed lengths)\n")
    print("| arch | server | decode tok/s | total tok/s | occ | "
          "lat p50/p99 (s) | TTFT p50/p99 (s) | DALI hit% |")
    print("|---|---|---|---|---|---|---|---|")
    for f in files:
        rec = json.load(open(f))
        for kind in sorted(rec["servers"]):
            r = rec["servers"][kind]
            print(f"| {rec['arch']} | {kind} | {r['decode_tok_s']:.1f} "
                  f"| {r['total_tok_s']:.1f} | {r['mean_occupancy']:.2f} "
                  f"| {r['lat_p50_s']:.2f}/{r['lat_p99_s']:.2f} "
                  f"| {r['ttft_p50_s']:.2f}/{r['ttft_p99_s']:.2f} "
                  f"| {100 * r['dali_hit_rate']:.1f} |")
    print("\n(decode tok/s: emitted decode tokens per decode-wall-second; "
          "TTFT: arrival to first token — see benchmarks/report_md.py "
          "serving_section docstring for interpretation.)")


if __name__ == "__main__":
    main()
