"""Roofline report (deliverable g): reads reports/dryrun/*.json produced by
``python -m repro.launch.dryrun --all`` and emits the per-(arch x shape x
mesh) three-term table with the dominant bottleneck."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Csv

DRYRUN_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "reports", "dryrun"))


def load_records():
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run(csv: Csv):
    recs = load_records()
    if not recs:
        csv.add("roofline/missing", 0.0,
                "run `python -m repro.launch.dryrun --all` first")
        return
    n_ok = n_skip = n_err = 0
    for r in recs:
        key = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skipped":
            n_skip += 1
            csv.add(key, 0.0, "skipped")
            continue
        if r["status"] != "ok":
            n_err += 1
            csv.add(key, 0.0, "ERROR")
            continue
        n_ok += 1
        rf = r["roofline"]
        mem = r["memory"]
        csv.add(key, rf["compute_s"] * 1e6,
                f"dom={rf['dominant'].replace('_s','')};"
                f"compute_ms={rf['compute_s']*1e3:.3f};"
                f"memory_ms={rf['memory_s']*1e3:.3f};"
                f"collective_ms={rf['collective_s']*1e3:.3f};"
                f"useful={rf['useful_flops_ratio']:.2f};"
                f"hbm_gb={mem['peak_per_device_gb']:.2f};"
                f"wmode={r.get('weight_mode','?')}")
    csv.add("roofline/summary", 0.0,
            f"ok={n_ok};skipped={n_skip};errors={n_err}")


if __name__ == "__main__":
    run(Csv())
