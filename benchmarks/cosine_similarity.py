"""Paper Table 8 + Appendix A.5: per-layer cosine similarity between the
features used for next-layer prediction and the true next-layer gate
inputs — raw (HybriMoE) vs residual-corrected (DALI)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, SHORT, load_model
from repro.core.residual import cosine_similarity


def run(csv: Csv, bs: int = 8):
    for arch in ("mixtral-8x7b", "qwen3-30b-a3b"):
        bm = load_model(arch)
        tr = bm.decode_trace(batch=bs, n_decode=16, seed=21)
        L = tr.n_moe_layers
        raw_all, cor_all = [], []
        for l in range(L - 1):
            raw, cor = [], []
            for t in range(tr.n_steps):
                h, hn = tr.gate_in[t][l], tr.gate_in[t][l + 1]
                raw.append(cosine_similarity(h, hn))
                cor.append(cosine_similarity(h + bm.res_vecs[l][None], hn))
            raw_all.append(np.mean(raw))
            cor_all.append(np.mean(cor))
            csv.add(f"table8_cosine/{SHORT[arch]}/layer{l}", 0.0,
                    f"HybriMoE={np.mean(raw):.3f};DALI={np.mean(cor):.3f}")
        csv.add(f"table8_cosine/{SHORT[arch]}/average", 0.0,
                f"HybriMoE={np.mean(raw_all):.3f};DALI={np.mean(cor_all):.3f}")


if __name__ == "__main__":
    run(Csv())
