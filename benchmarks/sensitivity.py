"""Paper Fig. 18a-c + Table 9: sensitivity to prefetch size, cache size,
and the (w_size, u_size) replacement parameters."""
from __future__ import annotations


from benchmarks.common import Csv, load_model
from repro.core.cache import WorkloadAwareCache
from repro.core.simulator import FrameworkSpec, simulate


def run(csv: Csv, bs: int = 8):
    bm = load_model("mixtral-8x7b")
    E = bm.cfg.moe.n_routed
    tr = bm.decode_trace(batch=bs, n_decode=24, seed=11)
    pfs = bm.prefetchers()

    # Fig 18a: prefetch size sweep
    for ps in (1, 2, 3):
        s = FrameworkSpec(f"PS{ps}", assignment="greedy",
                          prefetch="residual", prefetch_size=ps,
                          cache_policy="workload", cache_size=E // 4)
        r = simulate(tr, bm.cfg, bm.cost, s, prefetchers=pfs, batch=bs,
                     ctx_len=32)
        csv.add(f"fig18a_prefetch_size/Mixtral/PS{ps}",
                r.step_time_s * 1e6, f"tok_s={r.tokens_per_s:.2f}")

    # Fig 18b: cached expert count sweep
    for cs in range(1, E + 1, max(1, E // 4)):
        s = FrameworkSpec(f"C{cs}", assignment="greedy",
                          prefetch="residual", prefetch_size=1,
                          cache_policy="workload", cache_size=cs)
        r = simulate(tr, bm.cfg, bm.cost, s, prefetchers=pfs, batch=bs,
                     ctx_len=32)
        csv.add(f"fig18b_cache_size/Mixtral/C{cs}", r.step_time_s * 1e6,
                f"tok_s={r.tokens_per_s:.2f};hit={100*r.cache_hit_rate:.1f}%")

    # Fig 18c + Table 9: (w_size, u_size) grid — hit rate and speed
    bm_d = load_model("deepseek-v2-lite-16b")
    tr_d = bm_d.decode_trace(batch=bs, n_decode=32, seed=12)
    E_d = bm_d.cfg.moe.n_routed
    for w in (2, 4, 8):
        for u in (1, max(1, E_d // 8), max(2, E_d // 4)):
            hr = hit_rate_wu(tr_d, E_d, E_d // 2, w, u)
            s = FrameworkSpec(f"w{w}u{u}", assignment="greedy",
                              prefetch="residual", prefetch_size=1,
                              cache_policy="workload", cache_size=E_d // 2,
                              w_size=w, u_size=u)
            r = simulate(tr_d, bm_d.cfg, bm_d.cost, s,
                         prefetchers=bm_d.prefetchers(), batch=bs,
                         ctx_len=32)
            csv.add(f"fig18c_table9/DeepSeek/w{w}_u{u}",
                    r.step_time_s * 1e6,
                    f"hit={100*hr:.1f}%;tok_s={r.tokens_per_s:.2f}")


def hit_rate_wu(trace, E, cache_size, w, u):
    from repro.core.prefetch import top_workload_experts
    L = trace.n_moe_layers
    caches = [WorkloadAwareCache(E, cache_size, w_size=w, u_size=u, seed=l)
              for l in range(L)]
    hits = looks = 0
    for t in range(trace.n_steps):
        for l in range(L):
            wl = trace.workload[t][l]
            for e in top_workload_experts(wl, 3):
                if wl[e] > 0:
                    looks += 1
                    hits += bool(caches[l].hit(int(e)))
            caches[l].observe(wl)
    return hits / max(looks, 1)


if __name__ == "__main__":
    run(Csv())
