"""Paper Fig. 19: cumulative technique breakdown (Naive -> +Greedy ->
+Prefetch -> +Cache) on Mixtral and Qwen, and Fig. 5: PCIe-traffic share
vs HybriMoE."""
from __future__ import annotations

from benchmarks.common import Csv, SHORT, load_model
from repro.core.simulator import FrameworkSpec, paper_frameworks, simulate


def run(csv: Csv, bs: int = 8):
    for arch in ("mixtral-8x7b", "qwen3-30b-a3b"):
        bm = load_model(arch)
        E = bm.cfg.moe.n_routed
        ps = 1 if E <= 8 else 8
        tr = bm.decode_trace(batch=bs, n_decode=24, seed=5)
        pfs = bm.prefetchers()
        cache = max(1, E // 4)          # paper Fig 19: cache ratio 25%
        steps = [
            FrameworkSpec("Naive", assignment="all_cpu"),
            FrameworkSpec("+Greedy", assignment="greedy"),
            FrameworkSpec("+Prefetch", assignment="greedy",
                          prefetch="residual", prefetch_size=ps),
            FrameworkSpec("+Cache", assignment="greedy",
                          prefetch="residual", prefetch_size=ps,
                          cache_policy="workload", cache_size=cache,
                          w_size=4, u_size=8 if E >= 16 else 1),
        ]
        prev = None
        base = None
        for s in steps:
            r = simulate(tr, bm.cfg, bm.cost, s, prefetchers=pfs,
                         batch=bs, ctx_len=32)
            base = base or r.tokens_per_s
            inc = r.tokens_per_s / prev if prev else 1.0
            prev = r.tokens_per_s
            csv.add(f"fig19_breakdown/{SHORT[arch]}/{s.name}",
                    r.step_time_s * 1e6,
                    f"tok_s={r.tokens_per_s:.2f};cum_x{r.tokens_per_s/base:.2f};"
                    f"inc_x{inc:.2f}")

    # Fig 5: PCIe share, HybriMoE vs DALI
    for arch in ("mixtral-8x7b", "deepseek-v2-lite-16b"):
        bm = load_model(arch)
        E = bm.cfg.moe.n_routed
        tr = bm.decode_trace(batch=8, n_decode=24, seed=6)
        pfs = bm.prefetchers()
        for s in paper_frameworks(cache_size=E // 2):
            if s.name not in ("HybriMoE", "DALI"):
                continue
            r = simulate(tr, bm.cfg, bm.cost, s, prefetchers=pfs, batch=8,
                         ctx_len=32)
            csv.add(f"fig5_pcie_share/{SHORT[arch]}/{s.name}", 0.0,
                    f"pcie_frac={100*min(r.pcie_frac,1.0):.1f}%")


if __name__ == "__main__":
    run(Csv())
